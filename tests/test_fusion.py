"""The fuse_level ladder and the VMEM-driven tile chooser: verification
suite (see src/repro/kernels/README.md).

What is pinned here, mechanically:

  * tile invariance — the batched kernels produce BITWISE-identical
    outputs under different (tile_q, tile_n) pinnings of the same
    launch (each output element is an independent sum), and allclose
    vs the pure-jnp oracle (XLA may reassociate the nnz reduction
    differently outside the kernel, so oracle comparisons are not
    bitwise);
  * the candidate-driven gather_dot — parity vs host-side gather,
    sentinel slots at exactly -inf, and the ``cand_tiles_processed``
    host mirror matching the kernel's tile-skip predicate;
  * ``compact_candidates`` — order-preserving for live ids (the
    bit-exactness of fuse_level >= 1 rests on it);
  * fused router (flat + hierarchical) and fused refine stage parity
    vs the level-0 stages;
  * end-to-end: fuse_level 0/1/2 BITWISE-identical (scores, ids,
    docs_evaluated) across index variants x selector policies;
  * the tile chooser: alignment, caps, budget, fallback, determinism.
"""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import SeismicConfig, build_index
from repro.data import SyntheticSparseConfig, make_collection
from repro.graph import build_doc_graph, expand_neighbors
from repro.kernels import tiling
from repro.kernels.gather_dot.ops import (cand_tiles_processed,
                                          gather_dot_batch,
                                          gather_dot_cand_batch)
from repro.kernels.gather_dot.ref import gather_dot_batch_ref
from repro.kernels.refine_fused import refine_round_batch
from repro.kernels.summary_dot.ops import summary_dot_batch
from repro.kernels.summary_dot.ref import summary_dot_batch_ref
from repro.retrieval import SearchParams, search_pipeline
from repro.retrieval.prep import prep_queries
from repro.retrieval.router import route_batch
from repro.retrieval.scorer import compact_candidates, dedupe_batch
from repro.sparse.ops import PaddedSparse
from repro.sparse.quant import quantize_u8

DEGREE = 4


# ----------------------------------------------------------- fixtures

_cache: dict = {}


def _built():
    """(flat idx, hier idx, graph idx, quant-graph idx, queries) —
    built once per module."""
    if "fix" not in _cache:
        cfg = SyntheticSparseConfig(dim=512, n_docs=1024, n_queries=8,
                                    doc_nnz=32, query_nnz=12, n_topics=16,
                                    topic_coords=96, seed=11)
        docs_np, queries_np, _ = make_collection(cfg)
        docs = PaddedSparse(jnp.asarray(docs_np.coords),
                            jnp.asarray(docs_np.vals), docs_np.dim)
        queries = PaddedSparse(jnp.asarray(queries_np.coords),
                               jnp.asarray(queries_np.vals), queries_np.dim)
        icfg = SeismicConfig(lam=96, beta=8, alpha=0.4, block_cap=24,
                             summary_nnz=24)
        idx = build_index(docs, icfg, list_chunk=16)
        hidx = build_index(docs, dataclasses.replace(icfg,
                                                     superblock_fanout=4),
                           list_chunk=16)
        bp = SearchParams(k=DEGREE + 1, cut=8, block_budget=16,
                          policy="budget")
        gidx = build_doc_graph(idx, degree=DEGREE, batch=256,
                               build_params=bp)
        qidx = build_doc_graph(idx, degree=DEGREE, batch=256,
                               compact_forward=True, build_params=bp)
        _cache["fix"] = (idx, hidx, gidx, qidx, queries)
    return _cache["fix"]


def _assert_same_results(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------ batched kernels: tile sweeps

_GD_TILINGS = [(8, 128), (8, 256), (16, 128), (32, 256)]


@pytest.mark.parametrize("qn,n", [(8, 128), (13, 200), (5, 129)])
def test_gather_dot_batch_tile_invariance(qn, n):
    rng = np.random.default_rng(qn * 1000 + n)
    d, nnz = 512, 24
    q = jnp.asarray(rng.lognormal(0, 1, (qn, d)), jnp.float32)
    coords = jnp.asarray(rng.integers(0, d, (qn, n, nnz)), jnp.int32)
    vals = jnp.asarray(rng.lognormal(0, 1, (qn, n, nnz)), jnp.float32)
    want = np.asarray(gather_dot_batch_ref(q, coords, vals))
    outs = [np.asarray(gather_dot_batch(q, coords, vals, tile_q=tq,
                                        tile_n=tn, interpret=True))
            for tq, tn in _GD_TILINGS]
    for got in outs:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(got, outs[0])


def test_gather_dot_batch_quant_tile_invariance():
    """u8 fused-dequant plane: same tile-invariance contract."""
    rng = np.random.default_rng(77)
    qn, n, d, nnz = 11, 300, 512, 16
    q = jnp.asarray(rng.lognormal(0, 1, (qn, d)), jnp.float32)
    coords = jnp.asarray(rng.integers(0, d, (qn, n, nnz)), jnp.int32)
    vals = jnp.asarray(rng.lognormal(0, 1, (qn, n, nnz)), jnp.float32)
    q8, scale, zero = quantize_u8(vals)
    want = np.asarray(gather_dot_batch_ref(q, coords, q8, scale, zero))
    outs = [np.asarray(gather_dot_batch(q, coords, q8, scale, zero,
                                        tile_q=tq, tile_n=tn,
                                        interpret=True))
            for tq, tn in _GD_TILINGS]
    for got in outs:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(got, outs[0])


@pytest.mark.parametrize("qn,l", [(8, 128), (9, 97), (24, 260)])
@pytest.mark.parametrize("tq,tl", [(16, 128), (8, 256)])
def test_summary_dot_batch_tile_parity(qn, l, tq, tl):
    rng = np.random.default_rng(qn + l + tq)
    d, s = 1024, 32
    q = jnp.asarray(rng.lognormal(0, 1, (qn, d)), jnp.float32)
    coords = jnp.asarray(rng.integers(0, d, (qn, l, s)), jnp.int32)
    vals = rng.lognormal(0, 1, (qn, l, s)).astype(np.float32)
    vals[rng.random((qn, l, s)) < 0.3] = 0.0
    q8, scale, zero = quantize_u8(jnp.asarray(vals))
    got = np.asarray(summary_dot_batch(q, coords, q8, scale, zero,
                                       tile_q=tq, tile_l=tl,
                                       interpret=True))
    want = np.asarray(summary_dot_batch_ref(q, coords, q8, scale, zero))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    base = np.asarray(summary_dot_batch(q, coords, q8, scale, zero,
                                        tile_q=8, tile_l=128,
                                        interpret=True))
    np.testing.assert_array_equal(got, base)


# -------------------------------------- candidate-driven gather + skip

def _live_prefix_cand(rng, qn, c, n_docs, max_live):
    cand = np.full((qn, c), n_docs, np.int32)
    for i in range(qn):
        live = int(rng.integers(1, max_live))
        cand[i, :live] = rng.integers(0, n_docs, live)
    return jnp.asarray(cand)


def test_gather_dot_cand_batch_parity_and_skip_model():
    """Parity vs host-side gather; sentinel slots exactly -inf; the
    host mirror of the skip predicate marks exactly the tiles with at
    least one live candidate."""
    rng = np.random.default_rng(3)
    qn, c, n_docs, d, nnz = 10, 384, 512, 256, 12
    fwd_coords = jnp.asarray(rng.integers(0, d, (n_docs, nnz)), jnp.int32)
    fwd_vals = jnp.asarray(rng.lognormal(0, 1, (n_docs, nnz)), jnp.float32)
    q = jnp.asarray(rng.lognormal(0, 1, (qn, d)), jnp.float32)
    cand = _live_prefix_cand(rng, qn, c, n_docs, max_live=180)
    got = np.asarray(gather_dot_cand_batch(
        q, cand, fwd_coords, fwd_vals, n_docs=n_docs,
        tile_q=8, tile_n=128, interpret=True))
    safe = jnp.clip(cand, 0, n_docs - 1)
    want = np.asarray(gather_dot_batch_ref(
        q, jnp.take(fwd_coords, safe, axis=0),
        jnp.take(fwd_vals, safe, axis=0)))
    dead = np.asarray(cand) >= n_docs
    np.testing.assert_allclose(got[~dead], want[~dead],
                               rtol=1e-5, atol=1e-6)
    assert np.all(np.isneginf(got[dead]))
    proc = cand_tiles_processed(cand, n_docs, 8, 128)
    gq, gn = proc.shape
    padded = np.pad(np.asarray(cand), ((0, gq * 8 - qn), (0, gn * 128 - c)),
                    constant_values=n_docs)
    expect = (padded < n_docs).reshape(gq, 8, gn, 128).any(axis=(1, 3))
    np.testing.assert_array_equal(proc, expect)
    assert proc.sum() < proc.size     # the fixture really has dead tiles


def test_gather_dot_cand_batch_tile_invariance():
    rng = np.random.default_rng(4)
    qn, c, n_docs, d, nnz = 9, 200, 256, 128, 8
    fwd_coords = jnp.asarray(rng.integers(0, d, (n_docs, nnz)), jnp.int32)
    fwd_vals = jnp.asarray(rng.lognormal(0, 1, (n_docs, nnz)), jnp.float32)
    q = jnp.asarray(rng.lognormal(0, 1, (qn, d)), jnp.float32)
    cand = _live_prefix_cand(rng, qn, c, n_docs, max_live=c)
    outs = [np.asarray(gather_dot_cand_batch(
        q, cand, fwd_coords, fwd_vals, n_docs=n_docs,
        tile_q=tq, tile_n=tn, interpret=True))
        for tq, tn in [(8, 128), (8, 256), (16, 128)]]
    for got in outs[1:]:
        np.testing.assert_array_equal(got, outs[0])


def test_compact_candidates_order_preserving():
    """Compaction packs live ids into a prefix WITHOUT reordering them
    — the bit-exactness of fuse_level >= 1 merge tie-breaking rests on
    this."""
    rng = np.random.default_rng(5)
    n_docs = 100
    raw = jnp.asarray(rng.integers(0, n_docs, (6, 64)), jnp.int32)
    deduped = np.asarray(dedupe_batch(raw, n_docs))
    packed = np.asarray(compact_candidates(jnp.asarray(deduped)))
    for q in range(deduped.shape[0]):
        live = deduped[q][deduped[q] < n_docs]
        n_live = live.size
        np.testing.assert_array_equal(packed[q, :n_live], live)
        assert (packed[q, n_live:] == n_docs).all()


# ----------------------------------------------- fused stages vs level 0
#
# Stage-level comparisons run eagerly, so kernel-vs-host float sums may
# reassociate: finite scores compare allclose, masks and ids exactly.
# The end-to-end sweep below is BITWISE (same jit program structure).

def _routed(idx, queries, p):
    q_dense, lists, _ = prep_queries(queries.coords, queries.vals,
                                     idx.dim, p.cut)
    return route_batch(idx, q_dense, lists, p)


def test_fused_flat_router_stage_parity():
    idx, _, _, _, queries = _built()
    p = SearchParams(k=10, cut=8, block_budget=12)
    r0 = np.asarray(_routed(idx, queries, p).r)
    r2 = np.asarray(_routed(idx, queries,
                            dataclasses.replace(p, fuse_level=2)).r)
    assert r0.shape == r2.shape
    np.testing.assert_array_equal(np.isneginf(r0), np.isneginf(r2))
    m = np.isfinite(r0)
    np.testing.assert_allclose(r2[m], r0[m], rtol=1e-5, atol=1e-5)


def test_fused_hier_router_stage_parity():
    _, hidx, _, _, queries = _built()
    p = SearchParams(k=10, cut=8, block_budget=12, superblock_fanout=4,
                     superblock_budget=6)
    r0 = np.asarray(_routed(hidx, queries, p).r)
    r2 = np.asarray(_routed(hidx, queries,
                            dataclasses.replace(p, fuse_level=2)).r)
    np.testing.assert_array_equal(np.isneginf(r0), np.isneginf(r2))
    m = np.isfinite(r0)
    np.testing.assert_allclose(r2[m], r0[m], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("quant", [False, True])
def test_fused_refine_round_stage_parity(quant):
    """One fused round == expand + dedupe + seen-mask + compact +
    rescore: identical frontier ids, allclose scores, same -inf mask."""
    _, _, gidx, qidx, queries = _built()
    idx = qidx if quant else gidx
    from repro.retrieval.scorer import score_candidates
    p = SearchParams(k=10, cut=8, block_budget=12)
    q_dense, lists, _ = prep_queries(queries.coords, queries.vals,
                                     idx.dim, p.cut)
    _, ids, _ = search_pipeline(idx, queries, p)
    scored = jnp.where(ids >= 0, ids, idx.n_docs)
    cand_f, s_f = refine_round_batch(
        ids, scored, q_dense, idx.knn_ids, idx.fwd.coords, idx.fwd.vals,
        idx.fwd_scale, idx.fwd_zero, n_docs=idx.n_docs, degree=DEGREE)
    cand_u = dedupe_batch(expand_neighbors(idx, ids, DEGREE), idx.n_docs)
    seen = (cand_u[:, :, None] == scored[:, None, :]).any(-1)
    cand_u = compact_candidates(jnp.where(seen, idx.n_docs, cand_u))
    s_u = score_candidates(idx, q_dense, cand_u, False)
    np.testing.assert_array_equal(np.asarray(cand_f), np.asarray(cand_u))
    sf, su = np.asarray(s_f), np.asarray(s_u)
    np.testing.assert_array_equal(np.isneginf(sf), np.isneginf(su))
    m = np.isfinite(su)
    np.testing.assert_allclose(sf[m], su[m], rtol=1e-5, atol=1e-5)


# --------------------------------------------- end-to-end bitwise sweep

def _fuse_sweep(idx, queries, p):
    outs = [search_pipeline(idx, queries,
                            dataclasses.replace(p, fuse_level=lvl))
            for lvl in (0, 1, 2)]
    _assert_same_results(outs[0], outs[1])
    _assert_same_results(outs[0], outs[2])


@pytest.mark.parametrize("policy", ["budget", "adaptive",
                                    "global_threshold"])
def test_e2e_fuse_levels_bitexact_flat(policy):
    idx, _, _, _, queries = _built()
    _fuse_sweep(idx, queries,
                SearchParams(k=10, cut=8, block_budget=12, policy=policy))


def test_e2e_fuse_levels_bitexact_hier():
    _, hidx, _, _, queries = _built()
    _fuse_sweep(hidx, queries,
                SearchParams(k=10, cut=8, block_budget=12,
                             superblock_fanout=4, superblock_budget=6))


@pytest.mark.parametrize("quant", [False, True])
def test_e2e_fuse_levels_bitexact_refined(quant):
    _, _, gidx, qidx, queries = _built()
    _fuse_sweep(qidx if quant else gidx, queries,
                SearchParams(k=10, cut=8, block_budget=12,
                             graph_degree=DEGREE, refine_rounds=2))


# ------------------------------------------------------- tile chooser

def test_choose_tiles_alignment_caps_and_determinism():
    ch = tiling.choose_tiles(40, 1000, row_bytes=100, q_row_bytes=4096)
    assert ch.tile_q % tiling.SUBLANE == 0
    assert ch.tile_n % tiling.LANE == 0
    assert ch.tile_q <= tiling.MAX_TILE_Q
    assert ch.tile_n <= tiling.MAX_TILE_N
    # never wider than the padded problem
    assert ch.tile_q <= 40 + (-40) % tiling.SUBLANE
    assert ch.tile_n <= 1000 + (-1000) % tiling.LANE
    assert ch.fits and ch.vmem_bytes <= tiling.VMEM_BUDGET_BYTES
    assert ch == tiling.choose_tiles(40, 1000, row_bytes=100,
                                     q_row_bytes=4096)


def test_choose_tiles_prefers_wide_n_then_tall_q():
    # generous budget on a big problem -> both caps reached
    ch = tiling.choose_tiles(512, 65536, row_bytes=8, q_row_bytes=64)
    assert (ch.tile_q, ch.tile_n) == (tiling.MAX_TILE_Q, tiling.MAX_TILE_N)
    # a budget sized for exactly 8x256 shrinks the tile but stays legal
    tight = tiling.choose_tiles(
        512, 65536, row_bytes=8, q_row_bytes=64,
        vmem_budget=tiling.tile_vmem_bytes(8, 256, row_bytes=8,
                                           q_row_bytes=64))
    assert tight.fits
    assert tight.vmem_bytes <= ch.vmem_bytes
    assert (tight.tile_q, tight.tile_n) != (ch.tile_q, ch.tile_n)


def test_choose_tiles_fallback_on_pathological_rows():
    ch = tiling.choose_tiles(8, 128, row_bytes=10 ** 9, q_row_bytes=4)
    assert (ch.tile_q, ch.tile_n) == (tiling.SUBLANE, tiling.LANE)
    assert not ch.fits


def test_choose_tiles_rejects_degenerate_shapes():
    with pytest.raises(ValueError):
        tiling.choose_tiles(0, 128, row_bytes=4, q_row_bytes=4)


def test_choose_tile_q_budget_and_floor():
    per_q = 1024
    # fixed planes leave room for exactly 16 query rows
    fixed = tiling.VMEM_BUDGET_BYTES - 16 * per_q
    assert tiling.choose_tile_q(64, fixed_bytes=fixed,
                                per_query_bytes=per_q) == 16
    # over-budget planes still return the sublane floor
    assert tiling.choose_tile_q(
        64, fixed_bytes=2 * tiling.VMEM_BUDGET_BYTES,
        per_query_bytes=per_q) == tiling.SUBLANE
    # small batches never get a tile taller than their padded height
    assert tiling.choose_tile_q(3, fixed_bytes=0,
                                per_query_bytes=1) == tiling.SUBLANE


def test_bytes_moved_model_shape():
    small = tiling.bytes_moved(8, 256, 8, 128, row_bytes=64,
                               q_row_bytes=2048)
    big = tiling.bytes_moved(16, 512, 8, 128, row_bytes=64,
                             q_row_bytes=2048)
    assert big > small
    # wider candidate tiles re-fetch the query tile fewer times
    wide = tiling.bytes_moved(8, 512, 8, 256, row_bytes=64,
                              q_row_bytes=2048)
    narrow = tiling.bytes_moved(8, 512, 8, 128, row_bytes=64,
                                q_row_bytes=2048)
    assert wide < narrow
