"""Quality-observability plane: the consolidated recall implementation,
oracle parity, Wilson/SLO semantics, doc->block membership, the
per-stage loss-attribution funnel (total over misses), the shadow
auditor end to end through the async server, and the /quality.json +
/healthz endpoint contract.
"""
import json
import urllib.request

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.baselines import exact_search
from repro.core.build import doc_block_map
from repro.core.oracle import exact_topk
from repro.data import SyntheticSparseConfig, make_collection
from repro.obs import (Observability, ShadowAuditor, per_query_recall,
                       recall_at_k, sample_stats, start_exporter,
                       validate_trace, wilson_interval)
from repro.obs.quality import FUNNEL_STAGES, _OracleView
from repro.obs.registry import MetricsRegistry
from repro.retrieval import SearchParams
from repro.retrieval.pipeline import run_pipeline_staged, stage_fns
from repro.serve import AsyncSeismicServer
from repro.sparse.ops import PaddedSparse


def _params(**kw):
    kw.setdefault("k", 5)
    kw.setdefault("cut", 8)
    kw.setdefault("block_budget", 8)
    return SearchParams(**kw)


def _exact_ids(idx, coords, vals, k):
    """Per-query oracle ids through the SAME forward plane the auditor
    scores (dequantized when fwd_quant is on)."""
    view = _OracleView(idx)
    out = []
    for i in range(coords.shape[0]):
        _, eids = exact_topk(view.fwd_coords, view.fwd_vals, view.dim,
                             np.asarray(coords[i]), np.asarray(vals[i]),
                             k)
        out.append(eids)
    return np.stack(out)


# ------------------------------------------------- consolidated recall

def test_recall_sentinels_ties_duplicates():
    # -1 padding dropped from BOTH sides; duplicates collapse (sets)
    assert recall_at_k([1, 2, -1, 2], [1, 3, -1]) == pytest.approx(0.5)
    # ties are not forgiven: right score, wrong id is a miss
    assert recall_at_k([4], [5]) == 0.0
    # empty oracle row -> 0.0, never a ZeroDivisionError
    assert recall_at_k([1, 2], [-1, -1]) == 0.0
    assert recall_at_k([1, 2, 3], [3, 2, 1]) == 1.0


def test_recall_single_implementation():
    """Satellite: core.oracle and tune.sweep delegate to the one shared
    implementation in repro.obs.quality."""
    from repro.core import oracle
    from repro.tune.sweep import _per_query_recall
    cases = [([1, 2, -1], [2, 3]), ([0], [0]), ([5, 5], [5, 6, -1])]
    for a, e in cases:
        assert oracle.recall_at_k(np.array(a), np.array(e)) \
            == recall_at_k(a, e)
    ids = np.array([[1, 2], [3, -1]])
    eids = np.array([[2, 4], [3, 5]])
    np.testing.assert_array_equal(_per_query_recall(ids, eids),
                                  per_query_recall(ids, eids))
    np.testing.assert_array_equal(per_query_recall(ids, eids),
                                  [0.5, 0.5])


def test_wilson_interval_properties():
    assert wilson_interval(0, 0) == (0.0, 1.0)
    for s, n in [(0, 10), (10, 10), (7, 10), (93, 100), (1, 2)]:
        lo, hi = wilson_interval(s, n)
        assert 0.0 <= lo <= s / n <= hi <= 1.0
    # the interval tightens as evidence accumulates at fixed p
    lo1, hi1 = wilson_interval(9, 10)
    lo2, hi2 = wilson_interval(900, 1000)
    assert hi2 - lo2 < hi1 - lo1
    # higher z -> wider interval
    lo_s, hi_s = wilson_interval(7, 10, z=1.0)
    lo_w, hi_w = wilson_interval(7, 10, z=2.58)
    assert lo_w < lo_s and hi_w > hi_s


# ------------------------------------------------------- oracle parity

def test_exact_topk_matches_exact_search_baseline():
    """Satellite: the auditor's numpy oracle pins the jitted brute-force
    baseline across several synthetic collections."""
    k = 10
    for seed in (0, 3, 11):
        cfg = SyntheticSparseConfig(dim=512, n_docs=256, n_queries=4,
                                    doc_nnz=32, query_nnz=12,
                                    n_topics=8, topic_coords=64,
                                    seed=seed)
        docs_np, queries_np, _ = make_collection(cfg)
        docs = PaddedSparse(jnp.asarray(docs_np.coords),
                            jnp.asarray(docs_np.vals), docs_np.dim)
        queries = PaddedSparse(jnp.asarray(queries_np.coords),
                               jnp.asarray(queries_np.vals),
                               queries_np.dim)
        b_scores, b_ids = exact_search(docs, queries, k)
        b_scores, b_ids = np.asarray(b_scores), np.asarray(b_ids)
        for i in range(queries_np.coords.shape[0]):
            scores, ids = exact_topk(docs_np.coords,
                                     docs_np.vals.astype(np.float64),
                                     docs_np.dim, queries_np.coords[i],
                                     queries_np.vals[i], k)
            np.testing.assert_allclose(scores, b_scores[i],
                                       rtol=1e-5, atol=1e-5)
            # id SETS must agree whenever the k-th score is isolated
            # (f32 vs f64 may break exact ties differently)
            full = np.zeros(docs_np.dim, np.float64)
            np.add.at(full, queries_np.coords[i],
                      queries_np.vals[i].astype(np.float64))
            all_scores = (full[docs_np.coords] * docs_np.vals).sum(-1)
            kth = np.sort(all_scores)[::-1][k - 1:k + 1]
            if kth[0] - kth[1] > 1e-5:
                assert set(ids.tolist()) == set(b_ids[i].tolist())


# ------------------------------------------------- doc->block membership

def test_doc_block_map_matches_direct_scan(small_index):
    idx, _ = small_index
    indptr, mem_lists, mem_blocks = doc_block_map(idx)
    assert indptr.shape == (idx.n_docs + 1,)
    got = set()
    for d in range(idx.n_docs):
        for j in range(int(indptr[d]), int(indptr[d + 1])):
            got.add((d, int(mem_lists[j]), int(mem_blocks[j])))
    docs = np.asarray(idx.list_docs)
    lens = np.asarray(idx.list_len)
    off = np.asarray(idx.block_off)
    blen = np.asarray(idx.block_len)
    want = set()
    for ell in range(docs.shape[0]):
        for b in range(off.shape[1]):
            for p in range(int(off[ell, b]),
                           int(off[ell, b]) + int(blen[ell, b])):
                if p < int(lens[ell]) and int(docs[ell, p]) < idx.n_docs:
                    want.add((int(docs[ell, p]), ell, b))
    assert got == want


# ---------------------------------------------------------- the funnel

def test_funnel_attribution_total_over_misses(small_index,
                                              small_collection):
    """Every missed oracle doc lands in exactly one stage bucket, so
    the funnel sums to the miss count — with and without refinement."""
    from repro.graph import build_doc_graph
    idx, _ = small_index
    _, queries, *_ = small_collection
    graph_idx = build_doc_graph(idx, degree=4, batch=256)
    # starve the budget so the funnel has real losses to attribute
    for index, p in [(idx, _params(cut=4, block_budget=2)),
                     (graph_idx, _params(cut=4, block_budget=2,
                                         graph_degree=4,
                                         refine_rounds=1))]:
        aud = ShadowAuditor(index, p, MetricsRegistry(),
                            audit_sample_every=1)
        probed = {}
        out = run_pipeline_staged(index, queries.coords, queries.vals,
                                  p, fns=stage_fns(index, p),
                                  probe=probed.__setitem__, audit=True)
        ids = np.asarray(out[1])
        for i in range(queries.coords.shape[0]):
            aud.audit_once(np.asarray(queries.coords[i]),
                           np.asarray(queries.vals[i]), ids[i],
                           captures=probed, row=i)
        snap = aud.snapshot()
        assert snap["misses"] > 0          # the starved budget must bite
        assert set(snap["loss"]) == set(FUNNEL_STAGES)
        assert sum(snap["loss"].values()) == snap["misses"]
        # windowed live recall agrees with the offline computation
        exact = _exact_ids(index, np.asarray(queries.coords),
                           np.asarray(queries.vals), p.k)
        offline = float(np.mean(per_query_recall(ids, exact)))
        assert snap["window"]["live_recall"] == pytest.approx(offline)


# ----------------------------------------------------- auditor machine

def test_plan_cadence_is_global():
    aud = ShadowAuditor.__new__(ShadowAuditor)   # cadence logic only
    import threading
    aud.audit_sample_every = 4
    aud._lock = threading.Lock()
    aud._served = 0
    assert aud.plan(3) == (0,)     # global index 0
    assert aud.plan(3) == (1,)     # global index 4
    assert aud.plan(3) == (2,)     # global index 8
    assert aud.plan(3) == ()       # 9..11: nothing due
    assert aud.plan(5) == (0, 4)   # global indices 12 and 16
    assert aud.plan(0) == ()


def test_slo_state_machine(small_index, small_collection):
    idx, _ = small_index
    _, queries, *_ = small_collection
    c = np.asarray(queries.coords[0])
    v = np.asarray(queries.vals[0])
    k = 10
    (eids,) = _exact_ids(idx, c[None], v[None], k)

    # hits == trials -> ok
    ok = ShadowAuditor(idx, _params(k=k), MetricsRegistry(), target=0.95)
    ok.audit_once(c, v, eids)
    assert ok.slo_state == "ok"

    # live below target but Wilson interval still straddles it -> warn
    warn = ShadowAuditor(idx, _params(k=k), MetricsRegistry(),
                         target=0.95)
    near = eids.copy()
    near[0] = -1                               # 9/10 hits
    warn.audit_once(c, v, near)
    st = warn.window_stats()
    assert st["live_recall"] < 0.95 < st["wilson_hi"]
    assert warn.slo_state == "warn"

    # total miss -> the upper bound drops below target -> breach
    breach = ShadowAuditor(idx, _params(k=k), MetricsRegistry(),
                           target=0.95)
    breach.audit_once(c, v, np.full(k, -1))
    assert breach.window_stats()["wilson_hi"] < 0.95
    assert breach.slo_state == "breach"

    # no target attached -> ok forever, even at zero recall
    free = ShadowAuditor(idx, _params(k=k), MetricsRegistry())
    assert free.target is None
    free.audit_once(c, v, np.full(k, -1))
    assert free.slo_state == "ok"


def test_target_resolves_from_attached_tuned_policy(small_index):
    from repro.tune.policy import TunedPolicy, attach_tuned
    idx, _ = small_index
    pol = TunedPolicy(target=0.9, k=5, cut=8, block_budget=8,
                      policy="adaptive", measured_recall=0.95,
                      measured_cost=50.0)
    tuned = attach_tuned(idx, [pol])
    aud = ShadowAuditor(tuned, _params(policy="adaptive"),
                        MetricsRegistry())
    assert aud.target == 0.9
    other = ShadowAuditor(tuned, _params(policy="adaptive",
                                         block_budget=16),
                          MetricsRegistry())
    assert other.target is None            # knobs differ -> no match


def test_full_queue_sheds_never_blocks(small_index, small_collection):
    idx, _ = small_index
    _, queries, *_ = small_collection
    c = np.asarray(queries.coords[0])
    v = np.asarray(queries.vals[0])
    aud = ShadowAuditor(idx, _params(), MetricsRegistry(),
                        queue_bound=1)     # worker never started
    ids = np.zeros(5, np.int64)
    aud.feed(c, v, ids)
    aud.feed(c, v, ids)                    # queue full -> shed, no block
    aud.feed(c, v, ids)
    snap = aud.snapshot()
    assert snap["dropped"] == 2
    assert snap["audits"] == 0


def test_drift_reference_self_consistency(small_index, small_collection):
    """Audited traffic drawn FROM the tuning sample shows no drift:
    ratios 1, TV 0, in_sample 1."""
    idx, _ = small_index
    _, queries, *_ = small_collection
    coords = np.asarray(queries.coords)
    vals = np.asarray(queries.vals)
    ref = sample_stats(coords, vals, idx.dim)
    assert ref["n"] == coords.shape[0]
    aud = ShadowAuditor(idx, _params(), MetricsRegistry(),
                        reference=ref)
    for i in range(coords.shape[0]):
        aud.audit_once(coords[i], vals[i], np.zeros(5, np.int64))
    d = aud.drift()
    assert d["nnz_ratio"] == pytest.approx(1.0)
    assert d["l1_ratio"] == pytest.approx(1.0)
    assert d["topcoord_tv"] == pytest.approx(0.0)
    assert d["in_sample"] == 1.0
    # drift gauges exported only when a reference is attached
    snap = aud.registry.snapshot()
    assert "seismic_query_drift_in_sample" in snap
    (s,) = snap["seismic_query_drift_in_sample"]["samples"]
    assert s["value"] == 1.0


# ------------------------------------------- served traffic, end to end

def test_async_server_shadow_audit_end_to_end(small_index,
                                              small_collection):
    """Audit every request through the async server: live recall equals
    the offline recall of the returned ids, misses attribute fully, the
    audit span rides the request trace, and /quality.json + /healthz
    serve the plane."""
    idx, _ = small_index
    _, queries, *_ = small_collection
    coords = np.asarray(queries.coords)
    vals = np.asarray(queries.vals)
    n = coords.shape[0]
    p = _params(cut=4, block_budget=2)     # starved -> nonzero funnel
    obs = Observability.create(stage_sample_every=0)
    obs.auditor = ShadowAuditor(idx, p, obs.registry,
                                audit_sample_every=1,
                                queue_bound=4 * n, window=4 * n)
    srv = AsyncSeismicServer(idx, p, max_batch=8, query_nnz=16,
                             deadline_s=1e-3, cache_size=0,
                             coalesce=False, obs=obs)
    results = []
    with srv, obs.auditor:
        for i in range(n):
            results.append(srv.submit(coords[i], vals[i]).result(20.0))
        obs.auditor.drain()
        with start_exporter(obs.registry, obs.tracer,
                            quality=obs.auditor.snapshot) as exp:
            with urllib.request.urlopen(exp.url + "/healthz") as r:
                assert r.status == 200
                assert json.load(r) == {"status": "ok"}
            with urllib.request.urlopen(exp.url + "/quality.json") as r:
                assert r.status == 200
                served = json.load(r)
    snap = obs.auditor.snapshot()
    assert snap["audits"] == n and snap["dropped"] == 0
    assert snap["errors"] == 0
    ids = np.stack([r.ids for r in results])
    exact = _exact_ids(idx, coords, vals, p.k)
    offline = float(np.mean(per_query_recall(ids, exact)))
    assert snap["window"]["live_recall"] == pytest.approx(offline)
    assert snap["misses"] > 0
    assert sum(snap["loss"].values()) == snap["misses"]
    # the endpoint serves the same plane (counters monotone between
    # snapshot calls, structure identical)
    assert served["k"] == p.k and served["audits"] <= snap["audits"]
    assert set(served["loss"]) == set(FUNNEL_STAGES)
    # loss counters reached the exported registry too
    reg = obs.registry.snapshot()
    loss_fam = reg["seismic_recall_loss_total"]["samples"]
    by_stage = {s["labels"]["stage"]: s["value"] for s in loss_fam}
    assert by_stage == {k: float(v) for k, v in snap["loss"].items()}
    # every trace validates and carries an audit span on batch leaders
    traces = obs.tracer.finished()
    assert len(traces) == n
    audit_spans = 0
    for tr in traces:
        validate_trace(tr)
        audit_spans += sum(s.name == "audit" for s in tr.spans)
    assert audit_spans >= 1


def test_funnel_table_renders(small_index, small_collection):
    from repro.obs.report import funnel_table
    idx, _ = small_index
    _, queries, *_ = small_collection
    aud = ShadowAuditor(idx, _params(), MetricsRegistry(), target=0.9)
    aud.audit_once(np.asarray(queries.coords[0]),
                   np.asarray(queries.vals[0]), np.zeros(5, np.int64))
    text = funnel_table(aud.snapshot())
    assert "live recall@5" in text
    assert "SLO:" in text and "target 0.900" in text
    for stage in FUNNEL_STAGES:
        assert stage in text
