"""int8 error-feedback gradient compression: numerical behaviour on a
real multi-device psum (subprocess, 8 devices)."""
from helpers import run_with_devices

CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.train.compression import compressed_psum, ef_init

mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
world = 8
g_local = rng.standard_normal((world, 64, 32)).astype(np.float32)
true_mean = g_local.mean(axis=0)

def body(g, e):
    synced, new_e = compressed_psum(dict(w=g[0]), dict(w=e[0]), ("data",))
    return synced["w"], new_e["w"]

with jax.set_mesh(mesh):
    fn = jax.shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")), check_vma=False)
    g_in = jnp.asarray(g_local)[:, None]          # [8,1,64,32] shard-major
    e = jnp.zeros_like(g_in)
    synced, e1 = jax.jit(fn)(g_in, e)

# one-round quantized mean close to the true mean (int8 precision)
s0 = np.asarray(synced)[0]
scale = np.abs(g_local).max() / 127.0
err = np.abs(s0 - true_mean).max()
assert err < 3 * scale, (err, scale)

# error feedback: same grads repeated -> accumulated mean converges
acc, ef = np.zeros_like(true_mean), jnp.zeros_like(g_in)
rounds = 30
with jax.set_mesh(mesh):
    for _ in range(rounds):
        synced, ef = jax.jit(fn)(g_in, ef)
        acc += np.asarray(synced)[0]
bias = np.abs(acc / rounds - true_mean).max()
assert bias < 0.3 * scale, (bias, scale)   # EF kills the quantization bias
print("OK compression", err, bias)
"""


def test_compressed_psum_ef():
    out = run_with_devices(CODE, n_devices=8)
    assert "OK compression" in out


def test_compression_wire_savings():
    """The synced payload is int8 on the wire: check the HLO carries a
    s32 (widened int8) psum instead of f32."""
    code = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.train.compression import compressed_psum
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))

def body(g, e):
    s, ne = compressed_psum(dict(w=g[0]), dict(w=e[0]), ("data",))
    return s["w"], ne["w"]

with jax.set_mesh(mesh):
    fn = jax.shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")), check_vma=False)
    sds = jax.ShapeDtypeStruct((8, 1, 64, 32), jnp.float32)
    txt = jax.jit(fn).lower(sds, sds).compile().as_text()
import re
ars = [l for l in txt.splitlines() if "all-reduce" in l and "= s32" in l]
assert ars, "expected an s32 all-reduce for the compressed payload"
print("OK wire")
"""
    out = run_with_devices(code, n_devices=8)
    assert "OK wire" in out
