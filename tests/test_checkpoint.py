"""Checkpointing: atomic commit, resume, crash-mid-save, elastic re-mesh."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager, load_checkpoint, save_checkpoint
from repro.ckpt.checkpoint import latest_step
from helpers import run_with_devices


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return dict(
        w=jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
        nested=dict(b=jnp.asarray(rng.standard_normal(4), jnp.float32)),
        step=jnp.asarray(7, jnp.int32),
    )


def test_save_load_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 3, tree)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, step = load_checkpoint(str(tmp_path), like)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crash_mid_save_leaves_committed_intact(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    # simulate a crash: a stale .tmp dir from a dead writer
    os.makedirs(tmp_path / "step_00000002.tmp")
    (tmp_path / "step_00000002.tmp" / "garbage").write_text("partial")
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, step = load_checkpoint(str(tmp_path), like)
    assert step == 1  # the torn write is invisible


def test_manager_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    assert steps == [3, 4]


def test_resume_training_bit_exact(tmp_path):
    """Kill-and-restart: restoring (params, opt, step) reproduces the
    exact same trajectory as an uninterrupted run."""
    from repro.train import AdamWConfig, init_opt_state, make_train_step

    def loss(params, batch):
        return jnp.sum((params["w"] @ batch["x"]) ** 2)

    cfg = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50)
    step = jax.jit(make_train_step(loss, cfg))
    rng = np.random.default_rng(0)
    batches = [dict(x=jnp.asarray(rng.standard_normal((4,)), jnp.float32))
               for _ in range(8)]
    params = dict(w=jnp.asarray(rng.standard_normal((3, 4)), jnp.float32))
    opt = init_opt_state(params)

    # uninterrupted
    p_ref, o_ref = params, opt
    for b in batches:
        p_ref, o_ref, _ = step(p_ref, o_ref, b)

    # interrupted at step 4 + restored
    p, o = params, opt
    for b in batches[:4]:
        p, o, _ = step(p, o, b)
    save_checkpoint(str(tmp_path), 4, dict(params=p, opt=o))
    like = dict(params=jax.tree.map(jnp.zeros_like, p),
                opt=jax.tree.map(jnp.zeros_like, o))
    restored, _ = load_checkpoint(str(tmp_path), like)
    p, o = restored["params"], restored["opt"]
    for b in batches[4:]:
        p, o, _ = step(p, o, b)
    for a, b_ in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


ELASTIC_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.ckpt import save_checkpoint, load_checkpoint
import sys

path = sys.argv[1] if len(sys.argv) > 1 else "/tmp/elastic_ckpt"
# "big mesh" job: 8 devices, shard a tree, checkpoint it
mesh8 = jax.make_mesh((4, 2), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
w8 = jax.device_put(w, NamedSharding(mesh8, P("data", "model")))
save_checkpoint(path, 10, dict(w=w8))

# "small mesh" job: restore onto a 2-device mesh (elastic re-mesh)
mesh2 = jax.make_mesh((2, 1), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
like = dict(w=jax.ShapeDtypeStruct((8, 8), jnp.float32))
sh = dict(w=NamedSharding(mesh2, P("data", None)))
restored, step = load_checkpoint(path, like, shardings=sh)
assert step == 10
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
assert restored["w"].sharding.num_devices == 2
print("OK elastic")
"""


def test_elastic_remesh_restore(tmp_path):
    code = ELASTIC_CODE.replace('"/tmp/elastic_ckpt"',
                                repr(str(tmp_path / "ck")))
    out = run_with_devices(code, n_devices=8)
    assert "OK elastic" in out


# ---------------------- orphaned .tmp dirs + non-conforming step entries

def test_latest_step_ignores_nonconforming_entries(tmp_path):
    """A stray file/dir that merely LOOKS like a step entry used to
    raise ValueError inside latest_step and brick restore for the whole
    directory."""
    save_checkpoint(str(tmp_path), 5, _tree())
    (tmp_path / "step_final").mkdir()            # int("final") boom
    (tmp_path / "step_7.bak").write_text("x")    # int("7.bak") boom
    (tmp_path / "step_").mkdir()
    (tmp_path / "notes.txt").write_text("x")
    assert latest_step(str(tmp_path)) == 5
    like = jax.tree.map(lambda x: jnp.zeros_like(x), _tree())
    _, step = load_checkpoint(str(tmp_path), like)
    assert step == 5


def test_manager_start_cleans_orphaned_tmp_dirs(tmp_path):
    """A crash mid-save strands step_*.tmp dirs; a new manager over the
    same directory removes them before any save (and its _gc never
    trips over residual garbage)."""
    save_checkpoint(str(tmp_path), 1, _tree())
    orphan = tmp_path / "step_00000009.tmp"
    orphan.mkdir()
    (orphan / "shard_0.npz").write_text("torn")
    keepme = tmp_path / "step_custom_notes"      # non-conforming: kept
    keepme.mkdir()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    assert not orphan.exists()
    assert keepme.exists()
    mgr.save_async(2, _tree(2))
    mgr.wait()
    assert latest_step(str(tmp_path)) == 2


def test_save_over_orphaned_tmp_does_not_merge_stale_shards(tmp_path):
    """Re-saving a step whose .tmp survived a crash must start clean:
    the stale shard file must not ride into the committed checkpoint."""
    tmp = tmp_path / "step_00000003.tmp"
    tmp.mkdir()
    (tmp / "shard_99.npz").write_text("stale garbage")
    save_checkpoint(str(tmp_path), 3, _tree())
    committed = tmp_path / "step_00000003"
    assert committed.is_dir()
    assert not (committed / "shard_99.npz").exists()
    like = jax.tree.map(lambda x: jnp.zeros_like(x), _tree())
    _, step = load_checkpoint(str(tmp_path), like)
    assert step == 3
