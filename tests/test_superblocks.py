"""Multi-tier summary routing (BMP-style superblocks): verification
suite.

The coarse tier's contract is an UPPER BOUND: a superblock summary
coordinate-wise dominates every child block summary (element-wise max,
round-up requantized), so for any nonnegative query

    <q, sup(g)>  >=  <q, sum(j)>   for every block j in group g.

Everything here is mechanically checkable off that property:

  * upper-bound holds for random quantized indexes (deterministic
    sweep + hypothesis when installed);
  * safety invariant vs ``core/oracle.algorithm2``: every block the
    oracle evaluates clears its dynamic threshold, and the block's
    superblock bound clears it too — so threshold pruning at the
    coarse tier never prunes a block the oracle needs;
  * at sufficient ``superblock_budget`` the hierarchical route is
    bit-exact with the flat route (admits a superset-scoring candidate
    set at any budget, growing monotonically in the budget);
  * odd shapes: fanout not dividing n_blocks, single-block lists,
    all-padding superblocks, ``superblock_fanout=0`` bit-exact flat;
  * ckpt round-trip incl. pre-superblock back-compat.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import SeismicConfig, build_index
from repro.core.oracle import NumpyIndexView, algorithm2
from repro.data import SyntheticSparseConfig, make_collection
from repro.retrieval import SearchParams, router_work, search_pipeline
from repro.retrieval.router import route_batch
from repro.retrieval.prep import prep_queries
from repro.sparse.ops import PaddedSparse
from repro.sparse.quant import dequantize_u8, quantize_u8, quantize_u8_ceil

from helpers import given, needs_hypothesis, settings, st


# ----------------------------------------------------------- fixtures

def _collection(seed=7, dim=1024, n_docs=2048, n_queries=16):
    cfg = SyntheticSparseConfig(dim=dim, n_docs=n_docs, n_queries=n_queries,
                                doc_nnz=48, query_nnz=16, n_topics=32,
                                topic_coords=128, seed=seed)
    docs_np, queries_np, _ = make_collection(cfg)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    return docs, queries, queries_np


def _build(docs, fanout, lam=128, beta=8, block_cap=32, summary_nnz=32):
    cfg = SeismicConfig(lam=lam, beta=beta, alpha=0.4, block_cap=block_cap,
                        summary_nnz=summary_nnz, superblock_fanout=fanout)
    return build_index(docs, cfg, list_chunk=16), cfg


_built_cache: dict = {}


def _built(fanout, seed=7, **kw):
    key = (fanout, seed, tuple(sorted(kw.items())))
    if key not in _built_cache:
        docs, queries, queries_np = _collection(seed=seed)
        idx, cfg = _build(docs, fanout, **kw)
        _built_cache[key] = (docs, queries, queries_np, idx, cfg)
    return _built_cache[key]


def _np_summary_scores(idx):
    """Dequantized per-block and per-superblock summary score matrices
    for a dense query, as numpy closures."""
    sum_v = np.asarray(dequantize_u8(idx.sum_q, idx.sum_scale, idx.sum_zero))
    sup_v = np.asarray(dequantize_u8(idx.sup_q, idx.sup_scale, idx.sup_zero))
    sum_c = np.asarray(idx.sum_coords)
    sup_c = np.asarray(idx.sup_coords)

    def block_scores(q_dense):                      # [L, nb]
        return (q_dense[sum_c] * sum_v).sum(-1)

    def sup_scores(q_dense):                        # [L, ns]
        return (q_dense[sup_c] * sup_v).sum(-1)

    return block_scores, sup_scores


# ------------------------------------------------ upper-bound property

@pytest.mark.parametrize("fanout,seed", [(2, 0), (3, 1), (4, 2), (5, 3),
                                         (7, 4)])
def test_superblock_upper_bounds_children(fanout, seed):
    """<q, sup> >= <q, block summary> for every child, every query —
    incl. fanouts that do NOT divide n_blocks (12 % 5, 12 % 7 != 0)."""
    docs, queries, queries_np = _collection(seed=seed)
    idx, cfg = _build(docs, fanout)
    nb, ns, f = cfg.n_blocks, cfg.n_superblocks, fanout
    block_scores, sup_scores = _np_summary_scores(idx)
    blk_len = np.asarray(idx.block_len)
    rng = np.random.default_rng(seed)
    for _ in range(4):
        q_dense = rng.lognormal(0, 1, idx.dim).astype(np.float32)
        r = block_scores(q_dense)                   # [L, nb]
        u = sup_scores(q_dense)                     # [L, ns]
        for j in range(nb):
            g = j // f
            live = blk_len[:, j] > 0
            assert (u[live, g] >= r[live, j] - 1e-4 * np.abs(r[live, j])
                    - 1e-5).all(), (fanout, j)


def test_quantize_u8_ceil_never_rounds_down():
    rng = np.random.default_rng(11)
    v = rng.lognormal(0, 2, (64, 48)).astype(np.float32)
    v[rng.random(v.shape) < 0.3] = 0.0
    q, scale, zero = quantize_u8_ceil(jnp.asarray(v))
    recon = np.asarray(dequantize_u8(q, scale, zero))
    assert (recon >= v - 1e-4 * np.abs(v) - 1e-6).all()
    # padding (exact zeros) must reconstruct to exact zero
    assert (recon[v == 0] == 0).all()


@needs_hypothesis
@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(-3.0, 6.0),
       st.floats(0.1, 4.0), st.floats(0.0, 100.0))
def test_hypothesis_quantize_ceil_upper_bound_random_scale_zero(
        seed, mu, sigma, shift):
    """The round-up quantizer's upper bound must hold for ARBITRARY
    value ranges: ``mu``/``sigma`` sweep the quantization scale over
    ~9 orders of magnitude and ``shift`` pushes the zero point (vmin)
    far off the origin. The autotuner's hierarchical grid points trust
    this bound for whatever scale/zero a real collection produces."""
    rng = np.random.default_rng(seed)
    v = rng.lognormal(mu, sigma, (8, 24)).astype(np.float32)
    v[rng.random(v.shape) < 0.3] = 0.0
    v = np.where(v > 0, v + np.float32(shift), 0.0).astype(np.float32)
    q, scale, zero = quantize_u8_ceil(jnp.asarray(v))
    recon = np.asarray(dequantize_u8(q, scale, zero))
    assert (recon >= v - 1e-4 * np.abs(v) - 1e-6).all()
    # padding (exact zeros) must reconstruct to exact zero
    assert (recon[v == 0] == 0).all()


@needs_hypothesis
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 6))
def test_hypothesis_superblock_upper_bound_random_quantized(seed, fanout):
    """Random (non-collection) quantized summaries: rebuild the coarse
    tier's max-requantize by hand and check domination through BOTH
    quantizations for random nonnegative queries."""
    rng = np.random.default_rng(seed)
    nb, s, dim = int(rng.integers(2, 13)), 16, 256
    vals = rng.lognormal(0, 1, (nb, s)).astype(np.float32)
    vals[rng.random((nb, s)) < 0.3] = 0.0
    coords = rng.integers(0, dim, (nb, s))
    q, scale, zero = quantize_u8(jnp.asarray(vals))
    deq = np.asarray(dequantize_u8(q, scale, zero))
    ns = -(-nb // fanout)
    dense = np.zeros((ns, dim), np.float32)
    for j in range(nb):
        np.maximum.at(dense[j // fanout], coords[j], deq[j])
    s2 = min(fanout * s, dim)
    top = np.argsort(-dense, axis=-1)[:, :s2]
    tv = np.take_along_axis(dense, top, axis=-1)
    q2, scale2, zero2 = quantize_u8_ceil(jnp.asarray(tv))
    sup = np.asarray(dequantize_u8(q2, scale2, zero2))
    qd = rng.lognormal(0, 1, dim).astype(np.float32)
    r = (qd[coords] * deq).sum(-1)                  # [nb]
    u = (qd[top] * sup).sum(-1)                     # [ns]
    for j in range(nb):
        assert u[j // fanout] >= r[j] - 1e-4 * abs(r[j]) - 1e-5


# --------------------------------------- safety invariant vs algorithm2

def _oracle_safety(idx, cfg, queries_np, fanout, n_queries=8,
                   k=10, cut=8, heap_factor=0.8):
    """Every block algorithm2 keeps (summary >= theta/heap_factor at its
    final threshold) lives in a superblock whose coarse bound also
    clears the threshold — coarse threshold pruning is safe."""
    view = NumpyIndexView(idx)
    block_scores, sup_scores = _np_summary_scores(idx)
    blk_len = np.asarray(idx.block_len)
    f = fanout
    for qi in range(n_queries):
        qc = queries_np.coords[qi]
        qv = queries_np.vals[qi]
        scores, ids, _ = algorithm2(view, qc, qv, k, cut, heap_factor)
        if scores.size < k:
            continue
        theta = scores[-1] / heap_factor            # oracle's final bar
        q_dense = np.zeros(idx.dim, np.float32)
        np.add.at(q_dense, qc, qv)
        order = np.argsort(-qv, kind="stable")[:cut]
        probe = [int(qc[o]) for o in order if qv[o] > 0]
        r = block_scores(q_dense)
        u = sup_scores(q_dense)
        for i in probe:
            for j in range(cfg.n_blocks):
                if blk_len[i, j] > 0 and r[i, j] >= theta:
                    assert u[i, j // f] >= theta - 1e-4 * abs(theta) - 1e-5, \
                        (qi, i, j)


@pytest.mark.parametrize("fanout", [3, 5])
def test_safety_invariant_vs_algorithm2(fanout):
    docs, queries, queries_np = _collection()
    idx, cfg = _build(docs, fanout)
    _oracle_safety(idx, cfg, queries_np, fanout)


@needs_hypothesis
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6))
def test_hypothesis_safety_invariant_vs_algorithm2(seed, fanout):
    docs, queries, queries_np = _collection(seed=seed, n_docs=512,
                                            n_queries=4)
    idx, cfg = _build(docs, fanout, lam=64, beta=4, block_cap=16,
                      summary_nnz=16)
    _oracle_safety(idx, cfg, queries_np, fanout, n_queries=4)


# ------------------------------------------- two-stage router parity

def _route(idx, queries, p):
    q_dense, lists, _ = prep_queries(queries.coords, queries.vals,
                                     idx.dim, p.cut)
    return route_batch(idx, q_dense, lists, p), lists


@pytest.mark.parametrize("fanout", [3, 5])
def test_hierarchical_full_budget_bitexact_flat(fanout):
    """superblock_budget >= cut * n_superblocks: no stage-A pruning, so
    the hierarchical route must reproduce the flat route bit-exactly
    (stage B scores the IDENTICAL block-summary arrays)."""
    docs, queries, queries_np, idx, cfg = _built(fanout)
    pf = SearchParams(cut=8)
    ph = SearchParams(cut=8, superblock_fanout=fanout,
                      superblock_budget=8 * cfg.n_superblocks)
    bf, _ = _route(idx, queries, pf)
    bh, _ = _route(idx, queries, ph)
    np.testing.assert_array_equal(np.asarray(bf.r), np.asarray(bh.r))


def test_fanout0_bit_exact_with_flat_path():
    """superblock_fanout=0 params on a superblock-built index must take
    the flat code path and match a flat-built index bit-exactly."""
    docs, queries, queries_np, idx_h, _ = _built(4)
    idx_f, _ = _build(docs, 0)
    p = SearchParams(cut=8)
    bh, _ = _route(idx_h, queries, p)
    bf, _ = _route(idx_f, queries, p)
    np.testing.assert_array_equal(np.asarray(bh.r), np.asarray(bf.r))
    s0, i0, e0 = search_pipeline(idx_h, queries, p)
    s1, i1, e1 = search_pipeline(idx_f, queries, p)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_candidate_set_superset_in_budget():
    """Survivor sets grow monotonically with superblock_budget, so the
    selected block-score vector (sorted desc) dominates elementwise —
    'admits a superset-scoring candidate set' made mechanical."""
    docs, queries, queries_np, idx, cfg = _built(4)
    prev = None
    for m in (2, 4, 8, 8 * cfg.n_superblocks):
        p = SearchParams(cut=8, block_budget=16, policy="budget",
                         superblock_fanout=4, superblock_budget=m)
        batch, _ = _route(idx, queries, p)
        scores = np.sort(np.asarray(batch.r), axis=-1)[:, ::-1][:, :16]
        if prev is not None:
            finite = np.isfinite(prev)
            assert (scores[finite] >= prev[finite] - 1e-6).all(), m
        prev = scores
    # at full budget the hierarchical selection == flat selection
    bf, _ = _route(idx, queries, SearchParams(cut=8))
    flat = np.sort(np.asarray(bf.r), axis=-1)[:, ::-1][:, :16]
    finite = np.isfinite(flat)
    np.testing.assert_allclose(prev[finite], flat[finite])


def test_hierarchical_kernel_parity_odd_shapes():
    """use_kernel=True (interpret-mode Pallas) must match the jnp path
    on both tiers for a fanout that doesn't divide n_blocks."""
    docs, queries, queries_np, idx, cfg = _built(5)
    assert cfg.n_blocks % 5 != 0
    p0 = SearchParams(cut=8, superblock_fanout=5, superblock_budget=6)
    p1 = SearchParams(cut=8, superblock_fanout=5, superblock_budget=6,
                      use_kernel=True)
    b0, _ = _route(idx, queries, p0)
    b1, _ = _route(idx, queries, p1)
    r0, r1 = np.asarray(b0.r), np.asarray(b1.r)
    np.testing.assert_array_equal(np.isfinite(r0), np.isfinite(r1))
    f = np.isfinite(r0)
    np.testing.assert_allclose(r0[f], r1[f], rtol=1e-5, atol=1e-5)


def test_single_block_lists_and_fanout_exceeding_blocks():
    """lam == block_cap: each list has one capacity block per cluster;
    fanout > n_blocks collapses the coarse tier to one superblock per
    list and must still reproduce flat at full budget."""
    docs, queries, _ = _collection()
    idx, cfg = _build(docs, 8, lam=32, beta=1, block_cap=32,
                      summary_nnz=16)
    assert cfg.n_blocks == 2 and cfg.n_superblocks == 1
    pf = SearchParams(cut=8, k=10)
    ph = SearchParams(cut=8, k=10, superblock_fanout=8,
                      superblock_budget=8)
    bf, _ = _route(idx, queries, pf)
    bh, _ = _route(idx, queries, ph)
    np.testing.assert_array_equal(np.asarray(bf.r), np.asarray(bh.r))


def test_all_padding_superblocks_score_neg_inf():
    """Superblocks whose every child block is empty must rank last
    (-inf) and contribute no finite child scores."""
    docs, queries, queries_np, idx, cfg = _built(4)
    blk_len = np.asarray(idx.block_len)
    ns, f = cfg.n_superblocks, 4
    pad = (-cfg.n_blocks) % f
    alive = np.pad(blk_len > 0, ((0, 0), (0, pad))).reshape(-1, ns, f)
    sup_dead = ~alive.any(-1)                       # [L, ns]
    assert sup_dead.any(), "need at least one empty superblock"
    p = SearchParams(cut=8, superblock_fanout=4,
                     superblock_budget=8 * cfg.n_superblocks)
    batch, lists = _route(idx, queries, p)
    r = np.asarray(batch.r).reshape(queries.n, p.cut, cfg.n_blocks)
    lists = np.asarray(lists)
    for q in range(queries.n):
        for c in range(p.cut):
            li = lists[q, c]
            dead_blocks = ~(blk_len[li] > 0)
            assert (r[q, c, dead_blocks] == -np.inf).all()


def test_route_validation_errors():
    docs, queries, queries_np, idx_h, _ = _built(4)
    idx_f, _ = _build(docs, 0)
    q_dense, lists, _ = prep_queries(queries.coords, queries.vals,
                                     idx_f.dim, 8)
    with pytest.raises(ValueError, match="no superblock"):
        route_batch(idx_f, q_dense, lists,
                    SearchParams(cut=8, superblock_fanout=4))
    with pytest.raises(ValueError, match="mismatch"):
        route_batch(idx_h, q_dense, lists,
                    SearchParams(cut=8, superblock_fanout=2))


def test_router_work_accounting():
    cfg = SeismicConfig(lam=128, beta=8, block_cap=32, summary_nnz=32,
                        superblock_fanout=4)           # nb=12, ns=3
    flat = SearchParams(cut=8)
    hier = SearchParams(cut=8, superblock_fanout=4, superblock_budget=6)
    assert router_work(cfg, flat) == 8 * 12
    assert router_work(cfg, hier) == 8 * 3 + 6 * 4
    # budget clamps at the coarse axis
    big = SearchParams(cut=8, superblock_fanout=4, superblock_budget=10**6)
    assert router_work(cfg, big) == 8 * 3 + (8 * 3) * 4


# ----------------------------------------- end-to-end recall + ckpt

@pytest.mark.parametrize("policy", ["budget", "adaptive",
                                    "global_threshold"])
def test_hierarchical_recall_matches_flat(policy):
    """At a generous superblock budget the two-stage route must not
    cost recall vs flat routing for any selector policy."""
    from repro.core.baselines import exact_search
    from repro.core.oracle import recall_at_k
    docs, queries, queries_np, idx, cfg = _built(4)
    _, eids = exact_search(docs, queries, 10)

    def rec(p):
        _, ids, _ = search_pipeline(idx, queries, p)
        return np.mean([recall_at_k(np.asarray(ids[q]),
                                    np.asarray(eids[q]))
                        for q in range(queries.n)])
    pf = SearchParams(k=10, cut=8, block_budget=48, policy=policy)
    ph = SearchParams(k=10, cut=8, block_budget=48, policy=policy,
                      superblock_fanout=4, superblock_budget=12)
    rf, rh = rec(pf), rec(ph)
    assert rh >= rf - 0.02, (policy, rf, rh)


def test_index_ckpt_roundtrip_with_superblocks(tmp_path):
    from repro.ckpt import load_index, save_index
    docs, queries, queries_np, idx, cfg = _built(4)
    save_index(str(tmp_path), idx)
    save_index(str(tmp_path), idx)   # overwrite same step: no .old left
    idx2 = load_index(str(tmp_path))
    assert idx2.config == cfg
    p = SearchParams(k=10, cut=8, superblock_fanout=4, superblock_budget=8)
    s0, i0, e0 = search_pipeline(idx, queries, p)
    s1, i1, e1 = search_pipeline(idx2, queries, p)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))


def test_index_ckpt_pre_superblock_backcompat(tmp_path):
    """A checkpoint written WITHOUT the superblock tier (the old layout)
    must load as a flat-routing index with identical search results."""
    from repro.ckpt import load_index, save_index
    docs, queries, _ = _collection()
    idx, _ = _build(docs, 0)
    save_index(str(tmp_path), idx)
    idx2 = load_index(str(tmp_path))
    assert idx2.sup_coords is None and idx2.config.superblock_fanout == 0
    p = SearchParams(k=10, cut=8)
    s0, i0, _ = search_pipeline(idx, queries, p)
    s1, i1, _ = search_pipeline(idx2, queries, p)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_tree_ckpt_roundtrip_with_superblocks(tmp_path):
    """The generic tree checkpoint (save_checkpoint/load_checkpoint)
    also round-trips the extended index pytree."""
    from repro.ckpt import load_checkpoint, save_checkpoint
    docs, queries, queries_np, idx, cfg = _built(4)
    save_checkpoint(str(tmp_path), 1, idx)
    restored, step = load_checkpoint(str(tmp_path), idx)
    assert step == 1
    for a, b in zip(jax.tree.leaves(idx), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
