"""Regression tests for the serving/distributed correctness sweep:

  1. ``ServeFuture`` completion is first-writer-wins — a launch that
     raises after fulfilling part of its batch must not flip ``done``
     futures to ``error``.
  2. Pad-doc leak at the distributed merge seam — ``shard_collection``
     zero-pads the corpus; an all-zero pad row surfacing as a candidate
     scores exactly 0.0 and must be masked to ``(-inf, -1)`` before any
     cross-shard merge, never reaching the global top-k with an
     out-of-range global id.
  3. (mid-execution coalesce span validity lives in
     ``test_obs_serving.py`` next to the other trace-tree tests.)
"""
import numpy as np
import pytest
import jax.numpy as jnp

from helpers import run_with_devices
from repro.core.distributed import mask_shard_topk
from repro.retrieval import SearchParams
from repro.serve import AsyncSeismicServer, ServeFuture, ServeResult
from repro.sparse.ops import PaddedSparse


# ------------------------------------------- 1. future double completion

def test_future_completion_first_writer_wins():
    """Once completed, a future's (status, result) pair is immutable;
    the losing writer is told so."""
    f = ServeFuture()
    assert f._set("payload") is True
    assert f._fail("error: boom") is False          # loses the race
    assert f.status == "done" and f.result() == "payload"
    assert f._set("other") is False                 # done is done too

    g = ServeFuture()
    assert g._fail("shed") is True
    assert g._set("late result") is False
    assert g.status == "shed"
    with pytest.raises(RuntimeError, match="shed"):
        g.result()


def test_midlaunch_exception_preserves_fulfilled_futures(
        small_index, small_collection):
    """THE satellite bug: a launch raising after fulfilling part of its
    batch (here: the cache write of the second request explodes) fails
    only the unfulfilled futures; the already-``done`` one keeps its
    result, and the worker keeps serving."""
    idx, _ = small_index
    _, queries, *_ = small_collection
    srv = AsyncSeismicServer(
        idx, SearchParams(k=5, cut=8, block_budget=8),
        max_batch=2, query_nnz=16, deadline_s=0.05,
        cache_size=8, coalesce=False)
    real_put = srv.cache.put
    calls = []

    def exploding_put(key, value):
        calls.append(key)
        if len(calls) == 2:          # first request already fulfilled?
            raise RuntimeError("cache backend down")
        return real_put(key, value)

    srv.cache.put = exploding_put
    c, v = np.asarray(queries.coords), np.asarray(queries.vals)
    f0 = srv.submit(c[0], v[0])      # queued before the worker starts:
    f1 = srv.submit(c[1], v[1])      # one batch of exactly two requests
    with srv:
        assert f0.wait(10.0) and f1.wait(10.0)
        # cache.put for request 0 precedes request 1's, but request 0's
        # future is only fulfilled at the END of its loop iteration —
        # so the iteration-1 explosion hits with f0 done, f1 pending
        assert calls and len(calls) == 2
        assert f0.status == "done"
        assert isinstance(f0.result(), ServeResult)
        assert f1.status.startswith("error: RuntimeError")
        # the worker survived the batch failure and still serves
        f2 = srv.submit(c[2], v[2])
        assert f2.wait(10.0)
    assert f2.status == "done" and len(calls) == 3


# --------------------------------------------- 2. distributed pad leak

def test_mask_shard_topk_unit():
    """Pad rows (all-zero forward rows) and out-of-range ids go to
    (-inf, -1); live hits keep scores and gain the shard offset."""
    fwd = PaddedSparse(
        jnp.asarray([[1, 2], [3, 0], [0, 0], [0, 0]], jnp.int32),
        jnp.asarray([[1., 2.], [3., 0.], [0., 0.], [0., 0.]]), dim=8)
    ids = jnp.asarray([[0, 1, 2, -1],
                       [3, 1, -1, -1]], jnp.int32)
    scores = jnp.asarray([[5., 4., 0., -jnp.inf],
                          [0., 2., -jnp.inf, -jnp.inf]])
    out_s, out_g = mask_shard_topk(scores, ids, fwd, 40)
    np.testing.assert_array_equal(
        np.asarray(out_g), [[40, 41, -1, -1], [-1, 41, -1, -1]])
    np.testing.assert_array_equal(
        np.asarray(out_s),
        [[5., 4., -np.inf, -np.inf], [-np.inf, 2., -np.inf, -np.inf]])
    # explicit live bound masks ids past the corpus end even when the
    # forward row looks live
    out_s2, out_g2 = mask_shard_topk(scores, ids, fwd, 40, n_docs=41)
    np.testing.assert_array_equal(
        np.asarray(out_g2), [[40, -1, -1, -1], [-1, -1, -1, -1]])
    assert np.isneginf(np.asarray(out_s2)[0, 1])


DIST_CODE = r"""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.core import SeismicConfig, SearchParams
from repro.core.distributed import build_sharded_index, make_distributed_search
from repro.sparse.ops import PaddedSparse

assert len(jax.devices()) == 4
# 13 single-coord docs over 4 shards -> per_shard 4; shard 3 holds one
# live doc (global id 12) + THREE all-zero pad rows
n_docs, dim = 13, 32
coords = np.zeros((n_docs, 4), np.int32)
vals = np.zeros((n_docs, 4), np.float32)
coords[:, 0] = np.arange(n_docs)
vals[:, 0] = 1.0 + 0.01 * np.arange(n_docs)
docs = PaddedSparse(jnp.asarray(coords), jnp.asarray(vals), dim)
cfg = SeismicConfig(lam=8, beta=2, alpha=0.5, block_cap=4, summary_nnz=4)
stacked = build_sharded_index(docs, cfg, n_shards=4, list_chunk=8)
# cut=1: probe only the query's one live coord (padding coords are
# coord 0 / val 0 and would drag score-0.0 live docs into the tail)
p = SearchParams(k=4, cut=1, block_budget=4, policy="budget")
mesh = jax.make_mesh((1, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
search = make_distributed_search(mesh, p, doc_axes=("model",),
                                 data_axis="data", n_docs=n_docs)

# query hits ONLY doc 12 (the last shard's lone live doc); k=4 exceeds
# every shard's live hits, so the merged tail must be (-1, -inf) pads
qc = np.zeros((2, 4), np.int32); qc[:, 0] = 12
qv = np.zeros((2, 4), np.float32); qv[:, 0] = 1.0
with jax.set_mesh(mesh):
    s, ids = jax.jit(search)(stacked, jnp.asarray(qc), jnp.asarray(qv))
s, ids = np.asarray(s), np.asarray(ids)
assert (ids[:, 0] == 12).all(), ids
assert (ids[:, 1:] == -1).all(), ids
assert np.isneginf(s[:, 1:]).all(), s

# the leak mechanism itself: put a PAD row (shard-3 local id 3 = global
# 15 > 12) into the posting list the query probes — exactly the state a
# mutable/mmap index path can produce. The all-zero row scores 0.0;
# without the pre-gather mask it tops the merge with an out-of-range id.
leaky = dataclasses.replace(
    stacked, list_docs=stacked.list_docs.at[3, 12, 0].set(3))
with jax.set_mesh(mesh):
    s2, ids2 = jax.jit(search)(leaky, jnp.asarray(qc), jnp.asarray(qv))
s2, ids2 = np.asarray(s2), np.asarray(ids2)
assert (ids2 < n_docs).all(), ("pad doc leaked into global top-k", ids2)
live2 = ids2 >= 0
assert np.isfinite(s2[live2]).all()
assert np.isneginf(s2[~live2]).all()
print("OK pad mask")
"""


def test_distributed_merge_masks_pad_docs_4dev():
    """k above a shard's live-hit count never surfaces zero-padded rows
    (0.0 scores, out-of-range global ids) in the merged global top-k —
    including when a pad row sits in a posting list."""
    out = run_with_devices(DIST_CODE, n_devices=4)
    assert "OK pad mask" in out


# ----------------------- 3. stale cache / swap-tear across index mutation

def test_swap_index_invalidates_cached_results(small_index,
                                               small_collection):
    """THE stale-cache satellite bug: the result cache used to key on
    the query fingerprint alone, so any cached top-k survived an index
    swap/mutation forever. Keys now carry the serving epoch: after
    ``swap_index`` the old lines are unreachable and the same query is
    recomputed against the new index."""
    from repro.core import make_mutable
    idx, _ = small_index
    _, queries, *_ = small_collection
    srv = AsyncSeismicServer(
        idx, SearchParams(k=5, cut=8, block_budget=8),
        max_batch=8, query_nnz=16, deadline_s=0.02, cache_size=32)
    c = np.asarray(queries.coords[0])
    v = np.asarray(queries.vals[0])
    with srv:
        first = srv.submit(c, v).result(10.0)
        assert srv.submit(c, v).result(10.0).cached    # warm line
        top = int(first.ids[0])
        mut = make_mutable(idx)
        mut.delete_docs([top])                # the cached top-1 dies
        epoch0 = srv.epoch
        assert srv.swap_index(mut.index) == epoch0 + 1
        after = srv.submit(c, v).result(60.0)
        assert not after.cached               # stale line NOT served
        assert top not in after.ids           # fresh result, new index
        again = srv.submit(c, v).result(10.0)
        assert again.cached                   # re-cached under epoch 1
        assert top not in again.ids


def test_replica_mirror_swap_reaches_every_replica(small_index,
                                                   small_collection):
    """Mirror replicas used to snapshot (index, fns) ONCE before their
    serve loop — a swapped index never reached a running replica. The
    loop now re-reads the published replica list per batch."""
    from repro.core import make_mutable
    from repro.serve.replica import ReplicaSeismicServer
    idx, _ = small_index
    _, queries, *_ = small_collection
    srv = ReplicaSeismicServer(
        idx, SearchParams(k=5, cut=8, block_budget=8), n_replicas=2,
        max_batch=4, query_nnz=16, deadline_s=0.01, coalesce=False)
    c = np.asarray(queries.coords[0])
    v = np.asarray(queries.vals[0])
    with srv:
        top = int(srv.submit(c, v).result(10.0).ids[0])
        mut = make_mutable(idx)
        mut.delete_docs([top])
        srv.swap_index(mut.index)
        # sequential singles spread over both replicas via the balancer
        for _ in range(8):
            r = srv.submit(c, v).result(60.0)
            assert top not in r.ids
    assert srv.epoch == 1


def test_sync_facade_swap_bumps_epoch(small_index, small_collection):
    from repro.core import make_mutable
    from repro.serve.engine import SeismicServer
    idx, _ = small_index
    _, queries, *_ = small_collection
    srv = SeismicServer(idx, SearchParams(k=5, cut=8, block_budget=8),
                        max_batch=8)
    qs = PaddedSparse(queries.coords[:2], queries.vals[:2], queries.dim)
    top = int(srv.search(qs).ids[0, 0])
    mut = make_mutable(idx)
    mut.delete_docs([top])
    assert srv.swap_index(mut.index) == 1
    assert top not in srv.search(qs).ids[0]


# --------------------------- 4. fingerprint scale-bucket boundary flap

def test_fingerprint_stable_under_vmax_jitter():
    """THE cache-flap satellite bug: ``round(log2(vmax) * 8)`` put
    near-identical queries on opposite sides of a bucket edge. The
    candidate-set fix pins: for ANY scale, a ±0.2% vmax-jittered twin
    shares at least one cache key with the original."""
    from repro.serve.cache import (LRUCache, fingerprint_candidates,
                                   query_fingerprint)
    rng = np.random.default_rng(0)
    c = rng.choice(np.arange(1, 512), 16, replace=False).astype(np.int64)
    v = rng.uniform(0.2, 1.0, 16).astype(np.float32)
    saw_alt = False
    for scale in np.geomspace(0.5, 2.0, 65):
        base = fingerprint_candidates(c, v * np.float32(scale))
        saw_alt = saw_alt or len(base) > 1
        for jit in (1.002, 0.998):
            twin = fingerprint_candidates(
                c, v * np.float32(scale) * np.float32(jit))
            assert set(base) & set(twin), (scale, jit)
    assert saw_alt          # the sweep did cross guard bands
    # end-to-end through the LRU: insert under primary, twins hit
    cache = LRUCache(8)
    cache.put(fingerprint_candidates(c, v)[0], "payload")
    for jit in (1.002, 0.998):
        got = cache.get_any(
            fingerprint_candidates(c, v * np.float32(jit)))
        assert got == "payload"
    # the primary stays byte-identical to the legacy fingerprint
    assert fingerprint_candidates(c, v)[0] == query_fingerprint(c, v)
