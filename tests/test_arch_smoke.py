"""Per-architecture smoke tests: REDUCED config of the same family,
one forward/train step on CPU, asserting output shapes + no NaNs.
(Full configs are exercised only via the dry-run.)"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.api import get_bundle
from repro.train import AdamWConfig, init_opt_state, make_train_step

LM_ARCHS = ["phi3-medium-14b", "llama3-8b", "gemma3-27b",
            "kimi-k2-1t-a32b", "deepseek-v2-lite-16b"]
LM_DIMS = dict(global_batch=4, seq_len=32)

GNN_CELL_DIMS = {
    "full_graph_sm": dict(n_nodes=60, n_edges=240, d_feat=12, n_classes=4),
    "minibatch_lg": dict(n_nodes=500, n_edges=2000, batch_nodes=8,
                         fanout=(3, 2), d_feat=12, n_classes=4),
    "ogb_products": dict(n_nodes=80, n_edges=400, d_feat=10, n_classes=4),
    "molecule": dict(n_nodes=6, n_edges=10, batch=4, d_feat=8, n_classes=2),
}

RECSYS_ARCHS = ["sasrec", "bst", "fm", "wide-deep"]


def _no_nans(tree):
    for leaf in jax.tree.leaves(tree):
        assert not np.isnan(np.asarray(leaf, np.float32)).any()


def _train_smoke(bundle, cfg, dims, kind="train"):
    rng = np.random.default_rng(0)
    params = bundle.init(jax.random.PRNGKey(0), cfg, dims)
    batch = bundle.make_batch(rng, cfg, dims, kind)
    loss_fn = bundle.step(cfg, dims, kind)
    step = make_train_step(loss_fn, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                total_steps=10))
    opt = init_opt_state(params)
    p2, opt2, metrics = jax.jit(step)(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), loss
    _no_nans(p2)
    # a second step must reduce nothing structurally (shapes stable)
    p3, opt3, m3 = jax.jit(step)(p2, opt2, batch)
    assert np.isfinite(float(m3["loss"]))
    return loss, float(m3["loss"])


# ---------------------------------------------------------------- LM

@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_train_smoke(arch):
    bundle = get_bundle(arch)
    cfg = bundle.reduced
    loss1, loss2 = _train_smoke(bundle, cfg, LM_DIMS)
    # CE at init ~ log(vocab); extremely loose sanity band
    assert 0.5 < loss1 < 3 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_forward_shapes(arch):
    from repro.models.transformer import lm
    bundle = get_bundle(arch)
    cfg = bundle.reduced
    params = bundle.init(jax.random.PRNGKey(0), cfg, LM_DIMS)
    tokens = jnp.zeros((2, 16), jnp.int32)
    logits, aux = lm.forward(params, tokens, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    _no_nans(logits)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_decode_smoke(arch):
    from repro.models.transformer import lm
    bundle = get_bundle(arch)
    cfg = bundle.reduced
    dims = dict(global_batch=2, seq_len=48)
    params = bundle.init(jax.random.PRNGKey(0), cfg, dims)
    cache = bundle.init_cache(cfg, dims)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))
    tok = jnp.ones((2, 1), jnp.int32)
    for pos in range(3):
        logits, cache = step(params, cache, tok, jnp.asarray(pos, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert logits.shape == (2, cfg.vocab)
    _no_nans(logits)


def test_lm_decode_matches_forward():
    """Greedy decode logits must match the full forward pass (prefill
    via repeated decode) — validates caches, RoPE offsets, masking."""
    from repro.models.transformer import lm
    bundle = get_bundle("llama3-8b")
    cfg = bundle.reduced
    params = bundle.init(jax.random.PRNGKey(1), cfg, LM_DIMS)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    logits_full, _ = lm.forward(params, toks, cfg)
    cache = lm.init_cache(cfg, 2, 16)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))
    outs = []
    for i in range(8):
        lg, cache = step(params, cache, toks[:, i:i + 1],
                         jnp.asarray(i, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_gemma_decode_matches_forward():
    """Same check through the dual-cache (ring buffer) Gemma path, long
    enough that local ring buffers wrap (seq > window)."""
    from repro.models.transformer import lm
    bundle = get_bundle("gemma3-27b")
    cfg = bundle.reduced  # window 16
    params = bundle.init(jax.random.PRNGKey(1), cfg, LM_DIMS)
    rng = np.random.default_rng(0)
    s = 24  # > window 16 -> ring wraps
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, s)), jnp.int32)
    logits_full, _ = lm.forward(params, toks, cfg)
    cache = lm.init_cache(cfg, 1, 32)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))
    outs = []
    for i in range(s):
        lg, cache = step(params, cache, toks[:, i:i + 1],
                         jnp.asarray(i, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_mla_decode_matches_forward():
    """Absorbed MLA decode == naive full-sequence MLA forward.

    capacity_factor is raised so MoE never drops tokens — drop behavior
    legitimately differs between a 12-token forward and 2-token decode
    steps (different per-expert competition)."""
    import dataclasses as dc
    from repro.models.transformer import lm
    bundle = get_bundle("deepseek-v2-lite-16b")
    cfg = dc.replace(bundle.reduced, capacity_factor=64.0)
    params = bundle.init(jax.random.PRNGKey(2), cfg, LM_DIMS)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 6)), jnp.int32)
    logits_full, _ = lm.forward(params, toks, cfg)
    cache = lm.init_cache(cfg, 2, 8)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))
    outs = []
    for i in range(6):
        lg, cache = step(params, cache, toks[:, i:i + 1],
                         jnp.asarray(i, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_moe_routing_is_sparse():
    """MoE must route every token to exactly top_k experts and drop at
    most the capacity overflow."""
    from repro.models.transformer.ffn import _route, init_moe, moe_local
    bundle = get_bundle("kimi-k2-1t-a32b")
    cfg = bundle.reduced
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    idx, w, aux = _route(p["router"], x, cfg.moe_top_k)
    assert idx.shape == (64, cfg.moe_top_k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    out, aux = moe_local(p, x, cfg)
    assert out.shape == x.shape
    _no_nans(out)
    assert float(aux) >= 0.99  # E * sum f_e p_e >= 1 by Cauchy-Schwarz


# ---------------------------------------------------------------- GNN

@pytest.mark.parametrize("cell", list(GNN_CELL_DIMS))
def test_gnn_smoke(cell):
    bundle = get_bundle("gin-tu")
    cfg = bundle.reduced
    dims = GNN_CELL_DIMS[cell]
    loss1, loss2 = _train_smoke(bundle, cfg, dims)
    assert loss1 > 0


def test_gnn_aggregation_correct():
    """segment-sum message passing against a hand-built adjacency."""
    from repro.models.gnn.gin import _aggregate
    h = jnp.asarray([[1.0], [2.0], [4.0], [0.0]])
    edges = jnp.asarray([[0, 1], [1, 0], [2, 1], [3, 3]], jnp.int32)
    agg = _aggregate(h, edges, 4)
    np.testing.assert_allclose(np.asarray(agg[:, 0]), [2.0, 5.0, 0.0, 0.0])


def test_neighbor_sampler_shapes():
    from repro.models.gnn.sampler import CSRGraph, sample_subgraph, subgraph_shapes
    rng = np.random.default_rng(0)
    n, e = 200, 1000
    edges = rng.integers(0, n, (e, 2)).astype(np.int64)
    g = CSRGraph(n, edges)
    feats = rng.standard_normal((n, 12)).astype(np.float32)
    labels = rng.integers(0, 4, n).astype(np.int32)
    seeds = rng.choice(n, 8, replace=False)
    batch = sample_subgraph(rng, g, seeds, (3, 2), feats, labels)
    mn, me = subgraph_shapes(8, (3, 2))
    assert batch["feats"].shape == (mn, 12)
    assert batch["edges"].shape == (me, 2)
    assert (batch["edges"] < mn).all()
    assert (batch["labels"][:8] >= 0).all()
    # padded labels are -1
    assert (batch["labels"][mn - 1] == -1)


# ------------------------------------------------------------- recsys

@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_train_smoke(arch):
    bundle = get_bundle(arch)
    cfg = bundle.reduced
    loss1, _ = _train_smoke(bundle, cfg, dict(batch=32))
    assert 0 < loss1 < 10


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_serve_and_retrieval(arch):
    bundle = get_bundle(arch)
    cfg = bundle.reduced
    rng = np.random.default_rng(1)
    params = bundle.init(jax.random.PRNGKey(0), cfg, {})
    serve = bundle.step(cfg, dict(batch=8), "serve")
    batch = bundle.make_batch(rng, cfg, dict(batch=8), "serve")
    out = jax.jit(serve)(params, batch)
    assert out.shape[0] == 8
    _no_nans(out)
    retr = bundle.step(cfg, dict(batch=1, n_candidates=64), "retrieval")
    rbatch = bundle.make_batch(rng, cfg, dict(batch=1, n_candidates=64),
                               "retrieval")
    scores = jax.jit(retr)(params, rbatch)
    assert scores.shape == (64,)
    _no_nans(scores)


def test_fm_pairwise_identity():
    """FM sum-square trick == explicit pairwise sum."""
    from repro.models.recsys import fm
    bundle = get_bundle("fm")
    cfg = bundle.reduced
    params = bundle.init(jax.random.PRNGKey(0), cfg, {})
    rng = np.random.default_rng(0)
    batch = bundle.make_batch(rng, cfg, dict(batch=4), "train")
    got = np.asarray(fm.forward(params, batch["ids"], batch["dense"], cfg))
    # explicit O(F^2) reference
    from repro.models.recsys.embedding import field_offsets
    offs = field_offsets(cfg.table_rows)
    v = np.asarray(params["v"])
    wl = np.asarray(params["w_lin"])
    for b in range(4):
        vecs = [v[batch["ids"][b, f] + offs[f]] for f in range(cfg.n_sparse)]
        vecs += [np.asarray(params["v_dense"])[i] * float(batch["dense"][b, i])
                 for i in range(cfg.n_dense_feat)]
        pair = 0.0
        for i in range(len(vecs)):
            for j in range(i + 1, len(vecs)):
                pair += float(np.dot(vecs[i], vecs[j]))
        lin = sum(float(wl[batch["ids"][b, f] + offs[f], 0])
                  for f in range(cfg.n_sparse))
        want = (float(params["w0"]) + lin
                + float(np.asarray(batch["dense"][b]) @ np.asarray(params["w_dense"]))
                + pair)
        np.testing.assert_allclose(got[b], want, rtol=1e-4)


def test_embedding_bag_modes():
    from repro.models.recsys.embedding import embedding_bag, init_table
    table = init_table(jax.random.PRNGKey(0), 64, 8)
    ids = jnp.asarray([[1, 2, 3], [4, 0, 0]], jnp.int32)
    mask = jnp.asarray([[1, 1, 1], [1, 0, 0]], jnp.float32)
    s = embedding_bag(table, ids, mask, "sum")
    m = embedding_bag(table, ids, mask, "mean")
    np.testing.assert_allclose(np.asarray(s[1]), np.asarray(table[4]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m[0]), np.asarray(s[0]) / 3,
                               rtol=1e-6)
